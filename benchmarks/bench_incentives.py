"""Incentive-layer sweep: participation as a best-response game.

The selection axis so far assumed the SERVER owns the mask (ROADMAP item 4:
greedy/UCB/power-of-choice route a fixed budget by observed value). The
incentive layer inverts the ownership: each player joins a round iff its
utility — payment plus network-effect value minus a private cost — is
non-negative against everyone else's decision, and the realized mask is the
best-response fixed point (:class:`repro.core.incentives.
BestResponseParticipation`). The server's lever is no longer WHO but HOW
MUCH: the payment rule and price level.

Three sweeps, one artifact (``BENCH_incentives.json``):

- ``price_sweep``: the fixed payment rule at increasing price on the
  warm-start heterogeneity game. Realized participation tracks the
  continuum closed form ``s* = (p - c_min)/((c_max - c_min) - v)`` of the
  network-effects meta-game, and bytes-to-equilibrium is the server's
  procurement bill at each price point.
- ``collapse``: the free-rider cliff pinned as the honest negative. Any
  price at or below the cheapest player's cost sheds EVERY player from the
  all-in start — the best-response cascade is a death spiral, not a
  proportional decline: zero bytes move, the joint state freezes at x0,
  and no convergence metric improves. Under-funding a strategic federation
  does not buy a slower federation; it buys no federation.
- ``vs_greedy``: the incentive mask against PR 9's value-driven
  ``GreedyShapley`` at the same realized budget (k = 2 of 10 players).
  Payments route by COST, greedy routes by VALUE: when the cheap players
  happen to carry the error (``aligned``) the fixed-price coalition matches
  greedy without any value tracking, and when cost and value anti-correlate
  (``misaligned``, reversed cost grid) the purchased coalition is exactly
  the players who are already done — equal spend, no convergence. The pair
  brackets what a price CAN and CANNOT buy.

``python -m benchmarks.bench_incentives --json BENCH_incentives.json``
writes the artifact; ``scripts/render_experiments.py`` renders it into
EXPERIMENTS.md and ``scripts/check_bench_drift.py`` guards it.
"""

from __future__ import annotations

import json
import time

import jax
import numpy as np

from benchmarks.bench_selection import warm_start_game
from benchmarks.common import emit
from repro.core import stepsize
from repro.core.engine import PearlEngine
from repro.core.games.participation import NetworkEffectsParticipationGame
from repro.core.incentives import BestResponseParticipation
from repro.core.metrics import rounds_to_reach
from repro.core.selection import GreedyShapley

#: the meta-game value-of-the-crowd used throughout (must stay below
#: c_max - c_min = 0.6 for the closed form to apply)
VALUE = 0.2


def _row(name, r, threshold, rounds, bytes_full_round, **extra):
    hit = rounds_to_reach(r.rel_errors, threshold)
    final = float(r.rel_errors[-1])
    per_round = np.asarray(r.bytes_up) + np.asarray(r.bytes_down)
    return {
        "scheme": name,
        "rounds": rounds,
        "rounds_to_eq": hit,
        "bytes_to_eq": (int(per_round[:hit].sum())
                        if hit is not None else None),
        "bytes_total": int(per_round.sum()),
        "final_rel_error": final,
        "diverged": bool(not np.isfinite(final) or final > 1e3),
        "bytes_full_round": bytes_full_round,
        **extra,
    }


def _run(game, x0, sync, tau, rounds, gamma):
    return PearlEngine(sync=sync).run(
        game, x0, tau=tau, rounds=rounds, gamma=gamma,
        key=jax.random.PRNGKey(0), stochastic=False,
    )


def _full_round_bytes(game, x0, tau, rounds, gamma):
    """Per-round wire of the full-participation control — the denominator
    for realized participation rates and the pinned accounting constant."""
    full = PearlEngine().run(
        game, x0, tau=tau, rounds=2, gamma=gamma,
        key=jax.random.PRNGKey(0), stochastic=False,
    )
    up = int(np.asarray(full.bytes_up)[0])
    both = up + int(np.asarray(full.bytes_down)[0])
    return up, both


def run_price_sweep(tau: int = 4, rounds: int = 600,
                    threshold: float = 1e-3):
    """Fixed payment rule at increasing price: realized participation vs
    the continuum closed form, and the procurement bytes-to-equilibrium."""
    game, x0 = warm_start_game()
    gamma = stepsize.gamma_constant(game.constants(), tau)
    full_up, full_round = _full_round_bytes(game, x0, tau, rounds, gamma)

    rows = []
    t0 = time.perf_counter()
    for price in (0.15, 0.3, 0.45, 0.6, 0.9):
        meta = NetworkEffectsParticipationGame(
            n=game.n, price=price, value=VALUE)
        policy = BestResponseParticipation(price=price, value_weight=VALUE)
        r = _run(game, x0, policy, tau, rounds, gamma)
        realized = float(np.asarray(r.bytes_up).sum()
                         / max(full_up * rounds, 1))
        rows.append(_row(
            f"fixed@{price}", r, threshold, rounds, full_round,
            price=price, payment="fixed", tau=tau,
            closed_form_rate=meta.equilibrium_rate(),
            realized_participation=realized))
    us = (time.perf_counter() - t0) * 1e6 / max(len(rows), 1)

    emit("incentives_price", us,
         ";".join(f"p={r['price']}:s*={r['closed_form_rate']:.2f},"
                  f"s={r['realized_participation']:.2f},"
                  f"B={r['bytes_to_eq']}" for r in rows))
    return rows


def run_collapse(tau: int = 4, rounds: int = 200, threshold: float = 1e-3):
    """The free-rider cliff: price <= c_min sheds everyone. Pinned exactly —
    zero uplink bytes at ANY budget, because the cascade empties the
    coalition before the first sync."""
    game, x0 = warm_start_game()
    gamma = stepsize.gamma_constant(game.constants(), tau)
    _, full_round = _full_round_bytes(game, x0, tau, rounds, gamma)

    rows = []
    t0 = time.perf_counter()
    for price in (0.05, 0.15):
        policy = BestResponseParticipation(price=price, value_weight=VALUE)
        r = _run(game, x0, policy, tau, rounds, gamma)
        up_total = int(np.asarray(r.bytes_up).sum())
        rows.append(_row(
            f"fixed@{price}", r, threshold, rounds, full_round,
            price=price, payment="fixed", tau=tau,
            closed_form_rate=0.0,
            bytes_up_total=up_total,
            collapsed=bool(up_total == 0)))
    us = (time.perf_counter() - t0) * 1e6 / max(len(rows), 1)

    emit("incentives_collapse", us,
         ";".join(f"p={r['price']}:collapsed={r['collapsed']},"
                  f"up={r['bytes_up_total']}" for r in rows))
    return rows


def run_vs_greedy(tau: int = 4, rounds: int = 600, threshold: float = 1e-3,
                  fraction: float = 0.2):
    """Incentive coalition vs PR 9's greedy mask at the same budget (k = 2).

    ``price=0.35`` with ``value_weight=0`` buys exactly the two cheapest
    players every round (costs 0.23, 0.29 < 0.35 < 0.35 + 0.06) — the same
    per-round wire as ``GreedyShapley(fraction=0.2)``. The aligned row uses
    the default cost grid (the cheap players ARE the two far-from-
    equilibrium ones); the misaligned row reverses the grid, so the same
    price purchases the two players who are already done."""
    game, x0 = warm_start_game()
    gamma = stepsize.gamma_constant(game.constants(), tau)
    _, full_round = _full_round_bytes(game, x0, tau, rounds, gamma)
    grid = BestResponseParticipation().cost_vector(game.n)
    schemes = {
        "greedy_shapley": GreedyShapley(fraction=fraction),
        "best_response_aligned": BestResponseParticipation(
            price=0.35, value_weight=0.0),
        "best_response_misaligned": BestResponseParticipation(
            price=0.35, value_weight=0.0,
            costs=tuple(float(c) for c in np.asarray(grid)[::-1])),
    }

    rows = []
    t0 = time.perf_counter()
    for name, sync in schemes.items():
        r = _run(game, x0, sync, tau, rounds, gamma)
        rows.append(_row(name, r, threshold, rounds, full_round,
                         fraction=fraction, tau=tau))
    us = (time.perf_counter() - t0) * 1e6 / max(len(rows), 1)

    emit("incentives_vs_greedy", us,
         ";".join(f"{r['scheme']}:R={r['rounds_to_eq']},"
                  f"B={r['bytes_to_eq']}" for r in rows))
    return rows


def main() -> None:
    import argparse

    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--tau", type=int, default=4)
    parser.add_argument("--rounds", type=int, default=600,
                        help="budget for the price and vs-greedy sweeps")
    parser.add_argument("--collapse-rounds", type=int, default=200)
    parser.add_argument("--threshold", type=float, default=1e-3)
    parser.add_argument("--json", type=str, default=None, metavar="PATH",
                        help="write the sweeps as structured JSON "
                             "(BENCH_incentives.json convention)")
    args = parser.parse_args()

    price_rows = run_price_sweep(tau=args.tau, rounds=args.rounds,
                                 threshold=args.threshold)
    collapse_rows = run_collapse(tau=args.tau, rounds=args.collapse_rounds,
                                 threshold=args.threshold)
    greedy_rows = run_vs_greedy(tau=args.tau, rounds=args.rounds,
                                threshold=args.threshold)
    if args.json:
        payload = {"benchmark": "bench_incentives",
                   "price_sweep": price_rows,
                   "collapse": collapse_rows,
                   "vs_greedy": greedy_rows}
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=2)
        print(f"wrote {args.json}", flush=True)


if __name__ == "__main__":
    main()
