"""Figure 3: heatmap of relative error over the (gamma, tau) grid.

Deterministic PEARL-SGD on a 2-player quadratic game, 100 communication
rounds per cell. The paper's observations to reproduce:
  1. for fixed gamma, performance improves with tau up to a threshold, then
     degrades/diverges;
  2. the best-(gamma, tau) front follows gamma ~ 1/tau (a hyperbola).
Derived metrics: a monotone-then-worse check along a gamma row, and the
log-log slope of argmin_gamma(tau), which should be ~ -1.
"""

from __future__ import annotations

import time

import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.core.games import make_quadratic_game
from repro.core.pearl import pearl_sgd

GAMMAS = np.geomspace(1e-4, 3e-1, 14)
TAUS = np.array([1, 2, 3, 4, 6, 8, 12, 16, 24, 32])


def run(rounds: int = 100):
    game = make_quadratic_game(n=2, d=10, M=50, seed=2)
    x0 = jnp.asarray(np.random.default_rng(0).standard_normal((2, game.d)))

    grid = np.zeros((len(GAMMAS), len(TAUS)))
    t0 = time.perf_counter()
    for i, gamma in enumerate(GAMMAS):
        for j, tau in enumerate(TAUS):
            r = pearl_sgd(game, x0, tau=int(tau), rounds=rounds,
                          gamma=float(gamma), stochastic=False)
            e = r.rel_errors[-1]
            grid[i, j] = np.log10(e) if np.isfinite(e) and e > 0 else 20.0
    us = (time.perf_counter() - t0) * 1e6 / grid.size

    # observation 1: along a moderate-gamma row, error dips then rises
    row = grid[len(GAMMAS) // 2]
    dips = bool(np.argmin(row) > 0 or row[0] <= row[-1])
    improving_then_worse = bool(0 <= np.argmin(row) < len(TAUS) - 1
                                and row[-1] > row.min())
    # observation 2: best gamma per tau follows ~ 1/tau
    best_gamma = GAMMAS[np.argmin(grid, axis=0)]
    valid = np.isfinite(best_gamma)
    slope = np.polyfit(np.log(TAUS[valid]), np.log(best_gamma[valid]), 1)[0]
    emit("fig3_heatmap", us,
         f"hyperbola_slope={slope:.2f};dip_then_worse={improving_then_worse};"
         f"cells={grid.size};diverged={(grid >= 19).sum()}")
    return grid, slope


if __name__ == "__main__":
    run()
