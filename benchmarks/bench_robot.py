"""Figure 2c + Figure 6: distributed mobile-robot control (Section 4.2).

Fig 2c: stochastic PEARL-SGD with the Section 4.2 step-size
``1/(ell tau + L_max (tau-1) sqrt(kappa))`` — larger tau reaches lower error
in the same number of communication rounds.
Fig 6: per-robot objective traces stabilize (after transient oscillation from
competing interests) at the equilibrium for tau = 5.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.core import stepsize
from repro.core.games import make_robot_game
from repro.core.metrics import final_plateau
from repro.core.pearl import pearl_sgd, pearl_sgd_mean

TAUS = (1, 2, 4, 5, 8, 20)


def run(rounds: int = 400, n_seeds: int = 5):
    game = make_robot_game()
    c = game.constants()
    x0 = jnp.zeros((game.n, game.d))

    plateaus = {}
    t0 = time.perf_counter()
    for tau in TAUS:
        gamma = stepsize.gamma_robot(c, tau)
        mean, _ = pearl_sgd_mean(game, x0, tau=tau, rounds=rounds, gamma=gamma,
                                 n_seeds=n_seeds)
        plateaus[tau] = final_plateau(mean, 50)
    us = (time.perf_counter() - t0) * 1e6 / len(TAUS)
    emit("fig2c_robot_control", us,
         f"plateau_ratio_tau20={plateaus[20] / plateaus[1]:.3f};plateaus="
         + "|".join(f"tau{t}:{v:.2e}" for t, v in plateaus.items()))

    # ---- Fig 6: objective traces for tau = 5 ----
    tau = 5
    gamma = stepsize.gamma_robot(c, tau)
    r = pearl_sgd(game, x0, tau=tau, rounds=rounds, gamma=gamma,
                  key=jax.random.PRNGKey(0))
    x_star = game.equilibrium()
    f_star = [float(game.objective(i, x_star)) for i in range(game.n)]
    f_end = [float(game.objective(i, r.x_final)) for i in range(game.n)]
    gaps = [abs(a - b) / (abs(b) + 1e-9) for a, b in zip(f_end, f_star)]
    emit("fig6_robot_objectives", us,
         f"max_rel_gap_to_equilibrium={max(gaps):.3e};f_end="
         + "|".join(f"{v:.3f}" for v in f_end))
    return plateaus


if __name__ == "__main__":
    run()
