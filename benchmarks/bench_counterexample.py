"""Figure 4: Local SGD on the summed objective fails; PEARL-SGD converges.

Section B, equation (4): the bilinear couplings cancel in the sum, so joint
Local SGD follows a negatively-regularized field and diverges whenever
``lambda_min(A) < 1/10``, while PEARL-SGD (which respects the game structure)
converges to the equilibrium and the objective values stabilize.
"""

from __future__ import annotations

import time

import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.core import stepsize
from repro.core.baselines import local_sgd_on_sum
from repro.core.games import make_counterexample_game
from repro.core.pearl import pearl_sgd


def run(steps: int = 4000):
    game = make_counterexample_game()
    c = game.constants()
    x0 = jnp.ones((2, game.d))

    t0 = time.perf_counter()
    _, f1s, f2s, norms = local_sgd_on_sum(game, x0, steps=steps, gamma=0.05)
    tau = 2
    r = pearl_sgd(game, x0, tau=tau, rounds=steps // tau,
                  gamma=stepsize.gamma_constant(c, tau), stochastic=False)
    us = (time.perf_counter() - t0) * 1e6 / 2

    blowup = norms[-1] / norms[0]
    f_div = max(abs(f1s[-1]), abs(f2s[-1]))
    emit("fig4_localsgd_vs_pearl", us,
         f"localsgd_norm_blowup={blowup:.2e};localsgd_obj_end={f_div:.2e};"
         f"pearl_rel_err={r.rel_errors[-1]:.2e}")
    return blowup, r.rel_errors[-1]


if __name__ == "__main__":
    run()
