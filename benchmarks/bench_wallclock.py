"""Seconds-per-round for the PEARL wire matrix (BENCH_wallclock.json).

Every prior artifact in this repo measures *bytes* — the paper's
communication currency — and takes it on faith that fewer wire bytes buy
wall-clock time. This benchmark measures the seconds: the full compiled
engine scan (tau local steps + the sharded synchronization exchange) on
the fake 8-device mesh, for every sync strategy x engine mode cell:

- sync: exact f32 | bf16 | int8+EF | int4+EF (the sub-bf16 rows ship a
  single u8 payload per player block — 4 scale bytes + quantized lanes);
- engine: lockstep | async D=1 | async D=4 (uniform bounded staleness,
  device-resident snapshot ring buffer) | overlap (double-buffered wire,
  declared ConstantDelay(1)).

Each cell reports median/p90 seconds-per-round over timed repeats (after
a compile warmup), rounds-to-equilibrium from a convergence run, and the
two headline products: ``bytes_to_eq`` AND ``sec_to_eq``. Two guard
sections make the rows trustworthy rather than decorative:

- ``parity``: the async mesh engine at D=0 must equal the lockstep mesh
  engine BITWISE per sync strategy (the ring buffer adds no arithmetic);
- ``wire``: the compiled lockstep scan's cross-device collectives must
  carry exactly {u8} operands for int8/int4 (dry-run HLO, no timing).

Seconds are machine-local (pinned via :mod:`repro.launch.env`:
XLA fake-device flags, tcmalloc preload when available, silenced C++
logging) — the drift checker treats byte fields as exact and seconds as
schema-only. Skips gracefully on a single-device host.
"""

from __future__ import annotations

# Pin the process environment BEFORE jax is imported anywhere (the
# backend reads XLA_FLAGS once; LD_PRELOAD needs a re-exec). Safe and
# idempotent: sentinel-guarded, stdlib-only import.
if __name__ == "__main__":  # pragma: no cover - exercised by CI smoke
    from repro.launch.env import ensure_wallclock_env

    ensure_wallclock_env()

import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.core import collective, stepsize
from repro.core.async_engine import (
    AsyncPearlEngine,
    ConstantDelay,
    UniformDelay,
    ZeroDelay,
)
from repro.core.engine import (
    ExactSync,
    Int4Sync,
    Int8Sync,
    PearlEngine,
    QuantizedSync,
    _engine_scan,
)
from repro.core.games import make_quadratic_game

N, DIM = 8, 256        # 8 players fill the fake CI mesh; even DIM for int4
TAU = 4
EQ_THRESHOLD = 1e-3    # rel error below this counts as "at equilibrium"

SYNCS = {
    "exact": ExactSync(),
    "bf16": QuantizedSync(jnp.bfloat16),
    "int8": Int8Sync(),
    "int4": Int4Sync(),
}

# async rows use the delayed-adversary schedule; overlap is the declared
# ConstantDelay(1) the engine insists on (overlap IS one round of lag)
ENGINES = {
    "lockstep": lambda sync, mesh: PearlEngine(sync=sync, mesh=mesh),
    "async_d1": lambda sync, mesh: AsyncPearlEngine(
        sync=sync, mesh=mesh, delays=UniformDelay(seed=0), max_staleness=1),
    "async_d4": lambda sync, mesh: AsyncPearlEngine(
        sync=sync, mesh=mesh, delays=UniformDelay(seed=0), max_staleness=4),
    "overlap": lambda sync, mesh: AsyncPearlEngine(
        sync=sync, mesh=mesh, delays=ConstantDelay(1), max_staleness=1,
        overlap=True),
}

MAX_STALENESS = {"lockstep": 0, "async_d1": 1, "async_d4": 4, "overlap": 1}


def _mesh_or_none():
    try:
        return collective.player_mesh(N)
    except ValueError:
        return None


def _problem():
    """Game + a step size stable for EVERY cell of the matrix.

    Staleness shrinks the stable step-size region (the bounded-delay
    penalty of Thm staleness analyses): the lockstep-safe
    ``gamma_constant`` diverges under D = 4 on this game, so the whole
    matrix runs at 0.4x — one shared gamma keeps rounds-to-eq
    comparisons about the WIRE and the STALENESS, not about tuning.
    """
    game = make_quadratic_game(n=N, d=DIM, M=40, L_B=1.0, batch_size=1,
                               seed=0)
    gamma = 0.4 * stepsize.gamma_constant(game.constants(), TAU)
    x0 = jnp.asarray(
        np.random.default_rng(0).standard_normal((N, DIM)),
        dtype=jnp.float32,
    )
    return game, gamma, x0


def _rounds_to_eq(rel_errors: np.ndarray) -> int | None:
    """First round index at or below EQ_THRESHOLD, None if never reached."""
    hits = np.nonzero(np.asarray(rel_errors) <= EQ_THRESHOLD)[0]
    return int(hits[0]) if hits.size else None


def run_matrix(*, rounds: int, timed_rounds: int, warmup: int, repeats: int):
    """The headline sweep: seconds + bytes per cell of sync x engine."""
    mesh = _mesh_or_none()
    if mesh is None:
        emit("wallclock_matrix", 0.0, "skipped: single-device (set XLA_FLAGS="
             "--xla_force_host_platform_device_count=8)")
        return []
    game, gamma, x0 = _problem()
    key = jax.random.PRNGKey(0)

    rows = []
    for sname, sync in SYNCS.items():
        for ename, build in ENGINES.items():
            engine = build(sync, mesh)
            # convergence run: rounds-to-eq and the per-round byte ledger
            conv = engine.run(game, x0, tau=TAU, rounds=rounds, gamma=gamma,
                              key=key, stochastic=False)
            r_eq = _rounds_to_eq(conv.rel_errors)
            per_round = conv.bytes_up + conv.bytes_down
            bytes_to_eq = (int(per_round[:r_eq].sum())
                           if r_eq is not None else None)

            # timed repeats on a short scan (fresh jit cache entry for the
            # new rounds count, burned by the warmup calls)
            for _ in range(warmup):
                engine.run(game, x0, tau=TAU, rounds=timed_rounds,
                           gamma=gamma, key=key, stochastic=False)
            secs = []
            for _ in range(repeats):
                t0 = time.perf_counter()
                engine.run(game, x0, tau=TAU, rounds=timed_rounds,
                           gamma=gamma, key=key, stochastic=False)
                secs.append((time.perf_counter() - t0) / timed_rounds)
            med = float(np.median(secs))
            p90 = float(np.percentile(secs, 90))

            rows.append({
                "sync": sname,
                "engine": ename,
                "max_staleness": MAX_STALENESS[ename],
                "rounds": rounds,
                "bytes_per_round": int(per_round[0]),
                "rounds_to_eq": r_eq,
                "bytes_to_eq": bytes_to_eq,
                "rel_error_final": float(conv.rel_errors[-1]),
                "sec_per_round_median": med,
                "sec_per_round_p90": p90,
                "sec_to_eq": (med * r_eq) if r_eq is not None else None,
            })
            emit(f"wallclock_{sname}_{ename}", med * 1e6,
                 f"r_eq={r_eq},B/rnd={int(per_round[0])}")
    return rows


def run_d0_parity(*, rounds: int = 40):
    """The ring buffer must be free: async mesh at D=0 == lockstep mesh,
    bit for bit, for every sync strategy (including the EF residual path).
    """
    mesh = _mesh_or_none()
    if mesh is None:
        emit("wallclock_d0_parity", 0.0, "skipped: single-device")
        return []
    game, gamma, x0 = _problem()
    key = jax.random.PRNGKey(0)

    rows = []
    t0 = time.perf_counter()
    for sname, sync in SYNCS.items():
        lock = PearlEngine(sync=sync, mesh=mesh).run(
            game, x0, tau=TAU, rounds=rounds, gamma=gamma, key=key,
            stochastic=False)
        d0 = AsyncPearlEngine(sync=sync, mesh=mesh, delays=ZeroDelay(),
                              max_staleness=0).run(
            game, x0, tau=TAU, rounds=rounds, gamma=gamma, key=key,
            stochastic=False)
        bitwise = bool(np.array_equal(np.asarray(lock.x_final),
                                      np.asarray(d0.x_final)))
        assert bitwise, f"async D=0 drifted from lockstep under {sname}"
        rows.append({"sync": sname, "rounds": rounds,
                     "d0_bitwise_equal": bitwise})
    us = (time.perf_counter() - t0) * 1e6 / max(len(rows), 1)
    emit("wallclock_d0_parity", us,
         ";".join(f"{r['sync']}:bitwise" for r in rows))
    return rows


def run_wire_assertions(*, rounds: int = 4):
    """Dry-run HLO of the compiled lockstep scan: the cross-device
    collectives must carry u8 operands (and nothing wider) for int8/int4.
    """
    mesh = _mesh_or_none()
    if mesh is None:
        emit("wallclock_wire", 0.0, "skipped: single-device")
        return []
    game, gamma, x0 = _problem()
    gammas = jnp.full((rounds,), jnp.float32(gamma))
    key = jax.random.PRNGKey(0)

    expected = {"exact": None, "bf16": {"u16"},
                "int8": {"u8"}, "int4": {"u8"}}
    rows = []
    t0 = time.perf_counter()
    for sname, sync in SYNCS.items():
        engine = PearlEngine(sync=sync, mesh=mesh)
        hlo = _engine_scan.lower(
            game, x0, gammas, key, update=engine.update, sync=sync,
            topology=engine.topology, tau=TAU, stochastic=False,
            mesh=mesh, mesh_axis=engine.mesh_axis,
        ).compile().as_text()
        collective.assert_wire_dtype(hlo, compressed=(sname != "exact"))
        compressed = sorted(
            {o.operand_dtype for o in collective.compressed_wire_ops(hlo)})
        want = expected[sname]
        if want is not None:
            assert set(compressed) == want, (sname, compressed)
        rows.append({
            "sync": sname,
            "wire_dtypes": sorted({o.operand_dtype
                                   for o in collective.wire_dtype_report(hlo)}),
            "compressed_wire_dtypes": compressed,
        })
    us = (time.perf_counter() - t0) * 1e6 / max(len(rows), 1)
    emit("wallclock_wire", us,
         ";".join(f"{r['sync']}:{'+'.join(r['compressed_wire_dtypes']) or 'none'}"
                  for r in rows))
    return rows


def main(argv=None) -> None:
    import argparse

    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--rounds", type=int, default=150,
                        help="convergence-run length (rounds-to-eq window)")
    parser.add_argument("--timed-rounds", type=int, default=10,
                        help="scan length of each timed repeat")
    parser.add_argument("--warmup", type=int, default=1)
    parser.add_argument("--repeats", type=int, default=5)
    parser.add_argument("--json", type=str, default=None, metavar="PATH",
                        help="write the sweeps as structured JSON "
                             "(BENCH_wallclock.json convention)")
    args = parser.parse_args(argv)

    wire = run_wire_assertions()
    parity = run_d0_parity()
    rows = run_matrix(rounds=args.rounds, timed_rounds=args.timed_rounds,
                      warmup=args.warmup, repeats=args.repeats)
    if args.json:
        from repro.launch.env import find_tcmalloc
        payload = {
            "benchmark": "bench_wallclock",
            "device_count": jax.device_count(),
            "eq_threshold": EQ_THRESHOLD,
            "timing": {"warmup": args.warmup, "repeats": args.repeats,
                       "timed_rounds": args.timed_rounds,
                       "tcmalloc": find_tcmalloc() is not None},
            "rows": rows,
            "parity": parity,
            "wire": wire,
        }
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=2)
        print(f"wrote {args.json}", flush=True)


if __name__ == "__main__":
    main()
