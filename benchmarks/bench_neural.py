"""Bytes-to-loss + seconds-per-round for NEURAL players (BENCH_neural.json).

Every wall-clock artifact so far (BENCH_wallclock.json) times the dense
quadratic-game engine. This benchmark runs the real model stack: smollm
(smoke-reduced) players through :class:`repro.train.NeuralPlayerAdapter` on
the two-axis (players x model) fake mesh with the Pallas kernel path on —
the PR 8 end-to-end configuration — and measures the wire matrix:

- sync: exact f32 | bf16 | int8+EF (the error-feedback residual threads
  through the jitted round; its per-leaf f32 scales are billed);
- tau: 1 (the non-local baseline: sync every step) vs 4 local steps.

Each cell reports the billed bytes per round (uplink + the f32 mean
downlink), the loss trajectory, rounds/bytes to a fixed loss target, and
median/p90 seconds per round. Three guard sections keep the rows honest:

- ``wire``: the compiled round's player-axis all-gather operand dtype per
  sync, from dry-run HLO (u16 for bf16, u8 for int8 — never f32);
- ``roofline``: the billed bytes converted to production-mesh ICI seconds
  (the :mod:`repro.launch.perf` pod-collective term, ``bytes / ICI_BW``) —
  the link between the byte ledger and the napkin-math time model; the
  per-local-step column falls tau-fold by construction, which is the
  paper's Theorem 3.4 claim as a wire-time statement;
- in-benchmark asserts pin the predicted byte ratios (bf16 uplink = half of
  exact; int8 uplink = a quarter plus the per-leaf scale overhead).

Seconds are machine-local — the drift checker treats byte fields as exact,
loss fields at tolerance, and seconds as schema-only. Skips gracefully on a
single-device host (the committed artifact is the fake-8 run).
"""

from __future__ import annotations

if __name__ == "__main__":  # pragma: no cover - exercised by CI smoke
    from repro.launch.env import ensure_wallclock_env

    ensure_wallclock_env()

import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.configs import get_config
from repro.core import collective
from repro.core.engine import Int8Sync
from repro.data.synthetic import DataConfig, SyntheticTokenStream
from repro.optim.optimizers import sgd
from repro.train import NeuralPlayerAdapter

N_PLAYERS = 2
TAUS = (1, 4)
LOSS_TARGET = 6.5   # absolute lm_loss threshold (init is ~6.9 at vocab 512)

SYNCS = {
    "exact": {},
    "bf16": {"sync_dtype": jnp.bfloat16},
    "int8_ef": {"sync": Int8Sync()},
}

# the compiled sync all-gather's operand dtype per wire (dry-run HLO pin);
# exact is uncompressed so only f32 may appear
EXPECTED_GATHER = {"exact": {"f32"}, "bf16": {"u16"}, "int8_ef": {"u8"}}


def _cfg():
    return get_config("smollm-360m").smoke_variant()


def _stream(cfg):
    return SyntheticTokenStream(DataConfig(
        vocab_size=cfg.vocab_size, seq_len=32, batch_size=2,
        n_players=N_PLAYERS, seed=0,
    ))


def _adapter(cfg, tau, sync_kwargs):
    return NeuralPlayerAdapter(cfg, sgd(5e-2), n_players=N_PLAYERS, tau=tau,
                               prox_lambda=1e-3, seed=0, **sync_kwargs)


def _has_mesh():
    cfg = _cfg()
    return _adapter(cfg, 1, {}).mesh is not None


def _rounds_to_target(losses) -> int | None:
    hits = [i for i, l in enumerate(losses) if l <= LOSS_TARGET]
    return hits[0] if hits else None


def run_matrix(*, rounds: int, warmup: int, repeats: int):
    """sync x tau cells: losses, billed bytes, and timed repeats."""
    rows = []
    for sname, skw in SYNCS.items():
        for tau in TAUS:
            cfg = _cfg()
            adapter = _adapter(cfg, tau, skw)
            stream = _stream(cfg)
            hist = adapter.run(stream, rounds)
            losses = [h["lm_loss"] for h in hist]
            rep = adapter.comm_report()
            up, down = rep.per_round_bytes()
            per_round = int(up[0] + down[0])
            r_eq = _rounds_to_target(losses)

            for _ in range(warmup):
                adapter.run(stream, 1)
            secs = []
            for _ in range(repeats):
                t0 = time.perf_counter()
                adapter.run(stream, 1)
                secs.append(time.perf_counter() - t0)
            med = float(np.median(secs))
            p90 = float(np.percentile(secs, 90))

            rows.append({
                "sync": sname,
                "tau": tau,
                "rounds": rounds,
                "param_count": rep.param_count,
                "bytes_per_round": per_round,
                "uplink_bytes_per_round": int(up[0]),
                "uplink_overhead_bytes": rep.uplink_overhead_bytes,
                "loss_first": losses[0],
                "loss_final": losses[-1],
                "rounds_to_eq": r_eq,
                "bytes_to_eq": (per_round * r_eq
                                if r_eq is not None else None),
                "sec_per_round_median": med,
                "sec_per_round_p90": p90,
                "sec_to_eq": med * r_eq if r_eq is not None else None,
            })
            emit(f"neural_{sname}_tau{tau}", med * 1e6,
                 f"loss={losses[-1]:.4f},B/rnd={per_round}")

    # predicted byte ratios: the wire does what the dtype says it does
    by = {(r["sync"], r["tau"]): r for r in rows}
    for tau in TAUS:
        exact = by[("exact", tau)]["uplink_bytes_per_round"]
        bf16 = by[("bf16", tau)]["uplink_bytes_per_round"]
        int8 = by[("int8_ef", tau)]
        assert bf16 * 2 == exact, (bf16, exact)
        lanes = int8["uplink_bytes_per_round"] \
            - N_PLAYERS * int8["uplink_overhead_bytes"]
        assert lanes * 4 == exact, (lanes, exact)
    return rows


def run_wire_assertions():
    """Dry-run HLO of each compiled round: the player-axis gather operand
    must be the wire dtype — the claim that survives to the program."""
    rows = []
    t0 = time.perf_counter()
    for sname, skw in SYNCS.items():
        cfg = _cfg()
        adapter = _adapter(cfg, TAUS[-1], skw)
        hlo = adapter.lower_round_hlo(seq_len=32, batch_size=2)
        gathers = {o.operand_dtype
                   for o in collective.wire_dtype_report(hlo)
                   if o.op == "all-gather"}
        if sname != "exact":
            collective.assert_wire_dtype(hlo, compressed=True)
            assert EXPECTED_GATHER[sname] <= gathers, (sname, gathers)
            # the model-parallel axis may legitimately gather f32 shards;
            # the compressed set must be exactly the sync's container
            compressed = {o.operand_dtype
                          for o in collective.compressed_wire_ops(hlo)
                          if o.op == "all-gather"}
            assert compressed == EXPECTED_GATHER[sname], (sname, compressed)
        rows.append({
            "sync": sname,
            "wire_dtypes": sorted(
                {o.operand_dtype
                 for o in collective.wire_dtype_report(hlo)}),
            "compressed_gather_dtypes": sorted(
                {o.operand_dtype
                 for o in collective.compressed_wire_ops(hlo)
                 if o.op == "all-gather"}),
        })
    us = (time.perf_counter() - t0) * 1e6 / max(len(rows), 1)
    emit("neural_wire", us,
         ";".join(f"{r['sync']}:"
                  f"{'+'.join(r['compressed_gather_dtypes']) or 'none'}"
                  for r in rows))
    return rows


def run_roofline(matrix_rows):
    """Billed bytes -> production-mesh ICI seconds (the launch/perf.py
    pod-collective term): the time the wire would cost where it matters."""
    from repro.roofline.analysis import ICI_BW

    rows = []
    for r in matrix_rows:
        rows.append({
            "sync": r["sync"],
            "tau": r["tau"],
            "bytes_per_round": r["bytes_per_round"],
            "ici_s_per_round": r["bytes_per_round"] / ICI_BW,
            "ici_s_per_local_step": r["bytes_per_round"] / ICI_BW / r["tau"],
        })
    if rows:
        emit("neural_roofline", 0.0,
             ";".join(f"{r['sync']}/tau{r['tau']}:"
                      f"{r['ici_s_per_local_step']:.2e}s" for r in rows))
    return rows


def main(argv=None) -> None:
    import argparse

    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--rounds", type=int, default=6,
                        help="training rounds per cell (the committed "
                             "artifact and the CI smoke run the same scale)")
    parser.add_argument("--warmup", type=int, default=0,
                        help="extra warmup rounds before timing (the "
                             "training run already compiled the round)")
    parser.add_argument("--repeats", type=int, default=3)
    parser.add_argument("--json", type=str, default=None, metavar="PATH",
                        help="write the sweep as structured JSON "
                             "(BENCH_neural.json convention)")
    args = parser.parse_args(argv)

    if not _has_mesh():
        emit("neural_matrix", 0.0, "skipped: single-device (set XLA_FLAGS="
             "--xla_force_host_platform_device_count=8)")
        return

    wire = run_wire_assertions()
    rows = run_matrix(rounds=args.rounds, warmup=args.warmup,
                      repeats=args.repeats)
    roofline = run_roofline(rows)
    if args.json:
        from repro.launch.env import find_tcmalloc
        payload = {
            "benchmark": "bench_neural",
            "device_count": jax.device_count(),
            "arch": "smollm-360m (smoke)",
            "n_players": N_PLAYERS,
            "loss_target": LOSS_TARGET,
            "timing": {"warmup": args.warmup, "repeats": args.repeats,
                       "tcmalloc": find_tcmalloc() is not None},
            "rows": rows,
            "wire": wire,
            "roofline": roofline,
        }
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=2)
        print(f"wrote {args.json}", flush=True)


if __name__ == "__main__":
    main()
