"""Figure 5 (Section E.1): empirically tuned step-sizes per tau.

When theoretical constants are unknown, gamma is tuned over
{1e-1, ..., 1e-6} per tau; (tau, gamma) act as joint hyperparameters for
communication efficiency. Derived metrics: the best achievable error per tau
after a fixed number of communication rounds, deterministic and stochastic.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.core.games import make_quadratic_game
from repro.core.metrics import final_plateau
from repro.core.pearl import pearl_sgd, pearl_sgd_mean

TAUS = (1, 2, 4, 5, 8, 20)
GAMMAS = tuple(10.0 ** -e for e in range(1, 7))


def run(rounds: int = 150, n_seeds: int = 3):
    game = make_quadratic_game(n=5, d=10, M=100, batch_size=1, seed=0)
    x0 = jnp.asarray(np.random.default_rng(1).standard_normal((game.n, game.d)))

    t0 = time.perf_counter()
    best_det = {}
    for tau in TAUS:
        errs = []
        for gamma in GAMMAS:
            r = pearl_sgd(game, x0, tau=tau, rounds=rounds, gamma=gamma,
                          stochastic=False)
            e = r.rel_errors[-1]
            errs.append(e if np.isfinite(e) else np.inf)
        best_det[tau] = float(min(errs))
    us = (time.perf_counter() - t0) * 1e6 / (len(TAUS) * len(GAMMAS))
    emit("fig5a_tuned_deterministic", us, "best=" + "|".join(
        f"tau{t}:{v:.2e}" for t, v in best_det.items()))

    t0 = time.perf_counter()
    best_sto = {}
    for tau in TAUS:
        plats = []
        for gamma in GAMMAS:
            mean, _ = pearl_sgd_mean(game, x0, tau=tau, rounds=rounds,
                                     gamma=gamma, n_seeds=n_seeds)
            p = final_plateau(mean, 25)
            plats.append(p if np.isfinite(p) else np.inf)
        best_sto[tau] = float(min(plats))
    us = (time.perf_counter() - t0) * 1e6 / (len(TAUS) * len(GAMMAS))
    gain = best_sto[1] / best_sto[20]
    emit("fig5b_tuned_stochastic", us,
         f"tau20_vs_tau1_gain={gain:.2f};best=" + "|".join(
             f"tau{t}:{v:.2e}" for t, v in best_sto.items()))
    return best_det, best_sto


if __name__ == "__main__":
    run()
