"""Kernel micro-benchmarks: Pallas (interpret) vs jnp reference wall time and
— more meaningfully on CPU — HBM-traffic accounting for the flash path.

Wall times in interpret mode are NOT TPU performance; the derived metric that
matters is the analytic HBM-bytes ratio (naive vs flash), which is what the
roofline memory term uses in Section Perf.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import emit, time_fn
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.mamba2_scan.ops import ssd_scan
from repro.kernels.mamba2_scan.ref import ssd_ref
from repro.kernels.mlstm_chunk.ops import mlstm_scan
from repro.kernels.mlstm_chunk.ref import mlstm_ref


def flash_hbm_bytes(b, s, h, hd, block_q, bytes_per=2):
    """Analytic HBM traffic: naive materializes S^2 scores; flash streams."""
    naive = b * h * (2 * s * hd + 3 * s * s + s * hd) * bytes_per
    flash = b * h * (3 * s * hd + (s // block_q) * s * hd * 0 + s * hd) * bytes_per
    return naive, flash


def run():
    key = jax.random.PRNGKey(0)
    b, s, h, hd = 1, 256, 2, 64
    q = jax.random.normal(key, (b, s, h, hd))
    k = jax.random.normal(jax.random.fold_in(key, 1), (b, s, h, hd))
    v = jax.random.normal(jax.random.fold_in(key, 2), (b, s, h, hd))

    us_ref = time_fn(jax.jit(lambda q, k, v: attention_ref(q, k, v)), q, k, v)
    us_ker = time_fn(
        lambda q, k, v: flash_attention(q, k, v, block_q=64, block_k=64,
                                        interpret=True), q, k, v)
    naive, flash = flash_hbm_bytes(32, 32768, 48, 128, 128)
    emit("kernel_flash_attention", us_ker,
         f"ref_us={us_ref:.0f};interpret=True;"
         f"hbm_naive_GB={naive / 1e9:.1f};hbm_flash_GB={flash / 1e9:.1f};"
         f"traffic_ratio={naive / flash:.1f}x")

    L, H, P, N = 256, 4, 32, 16
    x = jax.random.normal(key, (b, L, H, P))
    dt = jax.nn.softplus(jax.random.normal(jax.random.fold_in(key, 3), (b, L, H)))
    A = -jnp.exp(0.3 * jax.random.normal(jax.random.fold_in(key, 4), (H,)))
    B = jax.random.normal(jax.random.fold_in(key, 5), (b, L, N))
    C = jax.random.normal(jax.random.fold_in(key, 6), (b, L, N))
    us_ref = time_fn(jax.jit(lambda *a: ssd_ref(*a)[0]), x, dt, A, B, C)
    us_ker = time_fn(lambda *a: ssd_scan(*a, chunk=64, interpret=True)[0],
                     x, dt, A, B, C)
    emit("kernel_mamba2_scan", us_ker,
         f"seq_ref_us={us_ref:.0f};interpret=True;chunk=64")

    dh = 32
    qm = jax.random.normal(key, (b, L, H, dh))
    km = jax.random.normal(jax.random.fold_in(key, 7), (b, L, H, dh))
    vm = jax.random.normal(jax.random.fold_in(key, 8), (b, L, H, dh))
    logi = jax.random.normal(jax.random.fold_in(key, 9), (b, L, H))
    logf = jax.nn.log_sigmoid(
        jax.random.normal(jax.random.fold_in(key, 10), (b, L, H)) + 2.0)
    us_ref = time_fn(jax.jit(lambda *a: mlstm_ref(*a)[0]), qm, km, vm, logi, logf)
    us_ker = time_fn(lambda *a: mlstm_scan(*a, chunk=64, interpret=True)[0],
                     qm, km, vm, logi, logf)
    emit("kernel_mlstm_chunk", us_ker,
         f"seq_ref_us={us_ref:.0f};interpret=True;chunk=64")


if __name__ == "__main__":
    run()
