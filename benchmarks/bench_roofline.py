"""Roofline table emission: reads the dry-run JSON records and prints one row
per (arch x shape x mesh) with the three terms and the bottleneck.

Run ``python -m repro.launch.dryrun --arch all --shape all --multi-pod no
--out experiments/dryrun_singlepod.json`` first (hours on this 1-core box);
this benchmark only formats whatever records exist.
"""

from __future__ import annotations

import json
import os

from benchmarks.common import emit

FILES = (
    "experiments/dryrun_singlepod.json",
    "experiments/dryrun_multipod.json",
)


def run():
    n = 0
    for path in FILES:
        if not os.path.exists(path):
            emit(f"roofline_missing_{os.path.basename(path)}", 0.0,
                 "run repro.launch.dryrun first")
            continue
        with open(path) as f:
            records = json.load(f)
        for r in records:
            if "error" in r:
                emit(f"roofline_{r['arch']}_{r['shape']}_{r['mesh']}", 0.0,
                     f"ERROR:{r['error'][:80]}")
                continue
            emit(
                f"roofline_{r['arch']}_{r['shape']}_{r['mesh']}",
                r.get("compile_s", 0.0) * 1e6,
                f"compute_s={r['compute_s']:.4f};memory_s={r['memory_s']:.4f};"
                f"collective_s={r['collective_s']:.4f};"
                f"bottleneck={r['bottleneck']};"
                f"useful_flops_ratio={r['useful_flops_ratio']:.3f};"
                f"peak_mem_GB_per_dev={r['peak_memory_bytes'] / 1e9:.2f}",
            )
            n += 1
    emit("roofline_total_rows", 0.0, f"rows={n}")


if __name__ == "__main__":
    run()
