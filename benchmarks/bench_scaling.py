"""Million-player scaling sweep: the O(d) mean-field wire vs the O(n d) joint.

The headline claim of the `JointView` refactor: with an aggregative game the
server never has to broadcast the joint action. A
:class:`~repro.core.engine.MeanFieldView` ships each player ``moments * d``
scalars per round — *independent of n* — and carries O(d) reference state,
so the same engine that runs n = 100 runs n = 10^6 on a laptop. Three
sections:

- ``mean_field``: n from 10^2 to 10^6 at fixed d. Per-player downlink bytes
  and per-player reference-state bytes must be FLAT in n (asserted in the
  sweep itself, re-asserted by CI against the committed artifact, and pinned
  exactly by ``scripts/check_bench_drift.py``).
- ``exact``: the legacy full-broadcast star at small n — per-player downlink
  grows linearly in n (n blocks of d scalars each), which is exactly why the
  exact path stops scaling.
- ``gap``: what the O(d) summary costs in accuracy. The self-corrected view
  (exact leave-one-out identity) matches the exact engine's iterate to float
  reduction order at every overlapping n, while the uncorrected
  (infinitesimal-player) view converges to the mean-field equilibrium whose
  distance to the true equilibrium shrinks as O(1/(n-1)) — both the
  closed-form gap and the converged-run gap are recorded per n and must
  decrease monotonically.

``python -m benchmarks.bench_scaling --json BENCH_scaling.json`` writes the
structured artifact; ``scripts/render_experiments.py`` renders it into
EXPERIMENTS.md (AUTO-BENCH-SCALING).
"""

from __future__ import annotations

import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.core import stepsize
from repro.core.engine import MeanFieldView, PearlEngine
from repro.core.games import make_mean_field_game
from repro.core.metrics import rounds_to_reach

MF_NS = (100, 1000, 10_000, 100_000, 1_000_000)
EXACT_NS = (100, 316, 1000)
D = 8
TAU = 4


def _run(game, view, rounds, *, record_trajectory=False):
    gamma = stepsize.gamma_constant(game.constants(), TAU)
    eng = PearlEngine() if view is None else PearlEngine(view=view)
    return eng.run(game, jnp.zeros((game.n, game.d)), tau=TAU, rounds=rounds,
                   gamma=gamma, key=jax.random.PRNGKey(0), stochastic=False,
                   record_trajectory=record_trajectory)


def run_mean_field(ns=MF_NS, rounds: int = 30, threshold: float = 1e-3):
    """The O(d) wire at scale: per-player bytes and state flat in n.

    ``record_trajectory`` stays off (the default): the scan carries one
    (n, d) iterate and emits O(rounds) scalars, so the n = 10^6 row needs
    the game + one iterate in memory, never a (rounds, n, d) stack.
    """
    view = MeanFieldView()
    rows = []
    t0 = time.perf_counter()
    for n in ns:
        game = make_mean_field_game(n=n, d=D, heterogeneity=1.0, seed=0)
        r = _run(game, view, rounds)
        per_round = r.bytes_up + r.bytes_down
        rows.append({
            "n": n,
            "d": D,
            "tau": TAU,
            "rounds": rounds,
            "bytes_per_round": int(per_round[0]),
            "bytes_up_per_player": int(r.bytes_up[0]) // n,
            "bytes_down_per_player": int(r.bytes_down[0]) // n,
            "ref_state_bytes_per_player":
                view.ref_scalars_per_player(n, D) * 4,
            "rounds_to_eq": rounds_to_reach(r.rel_errors, threshold),
            "final_rel_error": float(r.rel_errors[-1]),
        })
    # the scaling claim, asserted at the source: per-player wire and
    # reference state must not grow with n
    for f in ("bytes_up_per_player", "bytes_down_per_player",
              "ref_state_bytes_per_player"):
        vals = {row[f] for row in rows}
        assert len(vals) == 1, f"{f} not flat in n: {vals}"
    us = (time.perf_counter() - t0) * 1e6 / max(len(rows), 1)
    emit("scaling_mean_field", us,
         ";".join(f"n={r['n']}:down/player={r['bytes_down_per_player']}B,"
                  f"err={r['final_rel_error']:.1e}" for r in rows))
    return rows


def run_exact(ns=EXACT_NS, rounds: int = 30, threshold: float = 1e-3):
    """The legacy joint broadcast: per-player downlink linear in n."""
    rows = []
    t0 = time.perf_counter()
    for n in ns:
        game = make_mean_field_game(n=n, d=D, heterogeneity=1.0, seed=0)
        r = _run(game, None, rounds)
        per_round = r.bytes_up + r.bytes_down
        rows.append({
            "n": n,
            "d": D,
            "tau": TAU,
            "rounds": rounds,
            "bytes_per_round": int(per_round[0]),
            "bytes_up_per_player": int(r.bytes_up[0]) // n,
            "bytes_down_per_player": int(r.bytes_down[0]) // n,
            "ref_state_bytes_per_player": n * D * 4,
            "rounds_to_eq": rounds_to_reach(r.rel_errors, threshold),
            "final_rel_error": float(r.rel_errors[-1]),
        })
    downs = [row["bytes_down_per_player"] for row in rows]
    assert all(a < b for a, b in zip(downs, downs[1:])), \
        f"exact per-player downlink should grow with n: {downs}"
    us = (time.perf_counter() - t0) * 1e6 / max(len(rows), 1)
    emit("scaling_exact", us,
         ";".join(f"n={r['n']}:down/player={r['bytes_down_per_player']}B"
                  for r in rows))
    return rows


def run_gap(ns=EXACT_NS, rounds: int = 400, agree_rounds: int = 40,
            agree_atol: float = 1e-5):
    """Accuracy ledger at the overlapping n where both paths run.

    ``closed_form_gap`` is max|x* - x*_mf| from the two float64 solves;
    ``run_gap`` is the converged uncorrected-view iterate against the exact
    equilibrium (it finds the mean-field fixed point, so the run gap tracks
    the closed form); ``corrected_matches_exact`` pins that the
    self-corrected view reproduces the exact engine's iterate.
    """
    rows = []
    t0 = time.perf_counter()
    for n in ns:
        game = make_mean_field_game(n=n, d=D, heterogeneity=1.0, seed=0)
        x_star = np.asarray(game.equilibrium(), dtype=np.float64)
        mf_star = np.asarray(game.mean_field_equilibrium(), dtype=np.float64)
        r_unc = _run(game, MeanFieldView(self_correction=False), rounds)
        r_cor = _run(game, MeanFieldView(), agree_rounds)
        r_exact = _run(game, None, agree_rounds)
        corrected_diff = float(np.abs(
            np.asarray(r_cor.x_final) - np.asarray(r_exact.x_final)).max())
        rows.append({
            "n": n,
            "d": D,
            "closed_form_gap": float(np.abs(x_star - mf_star).max()),
            "run_gap": float(np.abs(
                np.asarray(r_unc.x_final, dtype=np.float64) - x_star).max()),
            "corrected_matches_exact": bool(corrected_diff <= agree_atol),
        })
    gaps = [row["closed_form_gap"] for row in rows]
    assert all(a > b for a, b in zip(gaps, gaps[1:])), \
        f"mean-field gap should shrink with n: {gaps}"
    assert all(row["corrected_matches_exact"] for row in rows), \
        "self-corrected view drifted from the exact engine"
    us = (time.perf_counter() - t0) * 1e6 / max(len(rows), 1)
    emit("scaling_gap", us,
         ";".join(f"n={r['n']}:gap={r['closed_form_gap']:.1e}"
                  for r in rows))
    return rows


def main() -> None:
    import argparse

    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--rounds", type=int, default=30,
                        help="rounds for the scaling sweeps (30 reaches the "
                             "1e-3 neighborhood at every n)")
    parser.add_argument("--gap-rounds", type=int, default=400,
                        help="budget for converging the uncorrected view "
                             "to its mean-field fixed point")
    parser.add_argument("--threshold", type=float, default=1e-3)
    parser.add_argument("--json", type=str, default=None, metavar="PATH",
                        help="write the sweeps as structured JSON "
                             "(BENCH_scaling.json convention)")
    args = parser.parse_args()

    print("name,us_per_call,derived")
    mf_rows = run_mean_field(rounds=args.rounds, threshold=args.threshold)
    exact_rows = run_exact(rounds=args.rounds, threshold=args.threshold)
    gap_rows = run_gap(rounds=args.gap_rounds)
    if args.json:
        payload = {"benchmark": "bench_scaling", "mean_field": mf_rows,
                   "exact": exact_rows, "gap": gap_rows}
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=2)
        print(f"wrote {args.json}", flush=True)


if __name__ == "__main__":
    main()
