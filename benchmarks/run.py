"""Benchmark entrypoint: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows. Heavier paper-figure
reproductions accept reduced iteration counts via BENCH_FAST=1 (default on)
so the full suite stays CPU-tractable; set BENCH_FAST=0 for paper-scale runs.
"""

from __future__ import annotations

import os
import traceback

FAST = os.environ.get("BENCH_FAST", "1") == "1"


def main() -> None:
    import sys

    if "--wallclock" in sys.argv:
        # Seconds-mode: pin the process env (re-exec once) BEFORE any jax
        # import, then hand the remaining flags to bench_wallclock.
        from repro.launch.env import ensure_wallclock_env

        ensure_wallclock_env()
        from benchmarks import bench_wallclock

        argv = [a for a in sys.argv[1:] if a != "--wallclock"]
        print("name,us_per_call,derived")
        bench_wallclock.main(argv)
        return
    from benchmarks import (
        bench_async,
        bench_collective,
        bench_counterexample,
        bench_engine,
        bench_heatmap,
        bench_kernels,
        bench_pearl_comm,
        bench_quadratic,
        bench_robot,
        bench_roofline,
        bench_scaling,
        bench_tuned,
    )

    print("name,us_per_call,derived")
    jobs = [
        ("quadratic", lambda: bench_quadratic.run(
            rounds_det=200 if FAST else 300,
            rounds_sto=1200 if FAST else 2000,
            n_seeds=3 if FAST else 5)),
        ("robot", lambda: bench_robot.run(
            rounds=300 if FAST else 400, n_seeds=3 if FAST else 5)),
        ("heatmap", lambda: bench_heatmap.run(rounds=100)),
        ("counterexample", lambda: bench_counterexample.run(
            steps=3000 if FAST else 4000)),
        ("tuned", lambda: bench_tuned.run(
            rounds=100 if FAST else 150, n_seeds=2 if FAST else 3)),
        ("engine", lambda: bench_engine.run(
            rounds=400 if FAST else 800)),
        ("engine_topology", lambda: bench_engine.run_topologies(
            rounds=2000 if FAST else 4000)),
        ("async_staleness", lambda: bench_async.run_staleness(
            rounds=1500 if FAST else 3000)),
        ("kernels", bench_kernels.run),
        ("pearl_comm", lambda: bench_pearl_comm.run(
            local_steps=16 if FAST else 24)),
        # emits a skip row on single-device runs; the CI multi-device job
        # (fake 8-device mesh) exercises the real sweep
        ("collective_wire", bench_collective.run_wire),
        ("collective_parity", lambda: bench_collective.run_parity(
            rounds=100 if FAST else 400)),
        ("roofline", bench_roofline.run),
        # mean-field scaling: per-player wire/state flat in n up to 10^6
        # (FAST caps the sweep at 10^5; the full run and the committed
        # BENCH_scaling.json carry the million-player row)
        ("scaling", lambda: (
            bench_scaling.run_mean_field(
                ns=bench_scaling.MF_NS[:-1] if FAST else bench_scaling.MF_NS),
            bench_scaling.run_exact(),
            bench_scaling.run_gap(rounds=200 if FAST else 400))),
    ]
    failures = []
    for name, fn in jobs:
        try:
            fn()
        except Exception as e:  # noqa: BLE001
            traceback.print_exc()
            failures.append(name)
            print(f"{name},0.0,ERROR:{e}")
    if failures:
        raise SystemExit(f"benchmark failures: {failures}")


if __name__ == "__main__":
    main()
