"""Async PEARL sweep: bytes-and-rounds-to-equilibrium vs the staleness bound.

The headline question for the bounded-staleness engine: how much of the
paper's tau-fold communication saving survives when players read stale
broadcasts? For each delay schedule and each staleness bound ``D`` the sweep
runs :class:`~repro.core.async_engine.AsyncPearlEngine` at matched ``tau``
and step size against the lockstep engine (the ``D = 0`` row IS the
lockstep trajectory — pinned bit-for-bit in tests/test_async_engine.py) and
reports rounds / wire bytes to reach the equilibrium neighborhood plus the
final relative error. Wire bytes per round are identical across ``D``
(staleness delays arrival, not transmission), so any cost shows up purely
as extra rounds.

The second sweep (``run_policy_rescue``) is the step-size-policy headline:
at STRONG coupling the fixed Theorem 3.4 step size diverges outright once
broadcasts are D = 16 rounds stale, while the ``delay_adaptive`` policy
(``gamma_i ~ tau / (tau + d_i)`` per player from the drawn staleness table)
converges to the equilibrium neighborhood — same game, same schedule, same
base step size. The D = 0 rows double as the bit-for-bit identity pin
(tests/test_stepsize_policies.py).

``python -m benchmarks.bench_async --json BENCH_async.json`` writes both
sweeps as a structured artifact (the BENCH_*.json convention);
``scripts/render_experiments.py`` renders the committed artifact into
EXPERIMENTS.md so the documented tables cannot drift from the data.
"""

from __future__ import annotations

import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.core import stepsize
from repro.core.async_engine import (
    AsyncPearlEngine,
    ConstantDelay,
    StragglerDelay,
    UniformDelay,
)
from repro.core.engine import PearlEngine
from repro.core.games import make_quadratic_game
from repro.core.metrics import rounds_to_reach

BOUNDS = (0, 1, 4, 16)

SCHEDULES = {
    "uniform": lambda: UniformDelay(seed=0),
    "straggler": lambda: StragglerDelay(fraction=0.25, seed=0),
    "constant": lambda: ConstantDelay(lag=10**9),   # clipped to D: worst case
}


def run_staleness(tau: int = 4, rounds: int = 3000, threshold: float = 1e-6,
                  bounds=BOUNDS, schedules=("uniform", "straggler")):
    """Rounds/bytes-to-equilibrium over D x delay-schedule at matched tau.

    Deterministic gradients isolate the staleness effect from sampling
    noise; the step size is the Theorem 3.4 rule for the matched tau, shared
    by every cell so the comparison is pure communication pattern.
    Weak-coupling game (L_B = 1, like the topology sweep): stale snapshots
    act like delays under the antisymmetric coupling, so at strong coupling
    large D destabilizes the Theorem 3.4 step size outright — here the cost
    shows up as extra rounds instead, which is the trackable quantity.
    """
    game = make_quadratic_game(n=6, d=10, M=40, L_B=1.0, batch_size=1, seed=0)
    c = game.constants()
    gamma = stepsize.gamma_constant(c, tau)
    x0 = jnp.asarray(
        np.random.default_rng(0).standard_normal((game.n, game.d)),
        dtype=jnp.float32,
    )

    sync_ref = PearlEngine().run(
        game, x0, tau=tau, rounds=rounds, gamma=gamma,
        key=jax.random.PRNGKey(0), stochastic=False,
    )
    sync_hit = rounds_to_reach(sync_ref.rel_errors, threshold)

    rows = []
    t0 = time.perf_counter()
    for sname in schedules:
        sched = SCHEDULES[sname]()
        for D in bounds:
            r = AsyncPearlEngine(delays=sched, max_staleness=D).run(
                game, x0, tau=tau, rounds=rounds, gamma=gamma,
                key=jax.random.PRNGKey(0), stochastic=False,
            )
            hit = rounds_to_reach(r.rel_errors, threshold)
            final = float(r.rel_errors[-1])
            per_round = r.bytes_up + r.bytes_down
            rows.append({
                "schedule": sname,
                "max_staleness": D,
                "tau": tau,
                "rounds": rounds,   # the budget, for budget-aware drift checks
                "rounds_to_eq": hit,
                "bytes_to_eq": (int(per_round[:hit].sum())
                                if hit is not None else None),
                "final_rel_error": final,
                "diverged": bool(not np.isfinite(final) or final > 1e3),
                "mean_staleness": r.mean_staleness,
                "bytes_per_round": int(per_round[0]),
                "lockstep_rounds_to_eq": sync_hit,
            })
    us = (time.perf_counter() - t0) * 1e6 / max(len(rows), 1)

    def _fmt(row):
        return (f"{row['schedule']}xD{row['max_staleness']}:"
                f"R={row['rounds_to_eq']},err={row['final_rel_error']:.1e}")

    emit("async_staleness", us, ";".join(_fmt(r) for r in rows))
    return rows


def run_policy_rescue(tau: int = 4, rounds: int = 2500,
                      threshold: float = 1e-6, bounds=(0, 4, 16),
                      policies=("theorem34", "delay_adaptive")):
    """Fixed vs delay-adaptive step size at STRONG coupling (the headline).

    Strong-coupling game (L_B = 5 — well past the staleness stability
    boundary, cf. the weak L_B = 1 game of :func:`run_staleness`), straggler
    schedule (a quarter of the players always maximally stale — the client-
    heterogeneity pattern of federated minimax settings): at D = 16 the
    fixed Theorem 3.4 step size diverges outright, while ``delay_adaptive``
    slows exactly the straggling players (``gamma_i ~ tau/(tau + d_i)``)
    and converges to the equilibrium neighborhood. The D = 0 cells pin the
    policies' trace-time identity: both run the SAME program.

    Honest boundary (recorded so nobody over-claims): under a UNIFORM
    all-players-stale schedule at this coupling the per-player correction
    still over-runs the margin — rescuing worst-case uniform staleness
    needs a uniform slow-down so large the rate dies with it; the win is
    heterogeneity, which is the practical regime.
    """
    game = make_quadratic_game(n=6, d=10, M=40, L_B=5.0, batch_size=1,
                               seed=0)
    c = game.constants()
    gamma = stepsize.gamma_constant(c, tau)
    x0 = jnp.asarray(
        np.random.default_rng(0).standard_normal((game.n, game.d)),
        dtype=jnp.float32,
    )
    sched = StragglerDelay(fraction=0.25, seed=0)

    rows = []
    t0 = time.perf_counter()
    for D in bounds:
        for pname in policies:
            r = AsyncPearlEngine(delays=sched, max_staleness=D,
                                 policy=pname).run(
                game, x0, tau=tau, rounds=rounds, gamma=gamma,
                key=jax.random.PRNGKey(0), stochastic=False,
            )
            final = float(r.rel_errors[-1])
            hit = rounds_to_reach(r.rel_errors, threshold)
            per_round = r.bytes_up + r.bytes_down
            rows.append({
                "schedule": "straggler",
                "policy": pname,
                "max_staleness": D,
                "tau": tau,
                "rounds": rounds,
                "rounds_to_eq": hit,
                "bytes_to_eq": (int(per_round[:hit].sum())
                                if hit is not None else None),
                "final_rel_error": final,
                "diverged": bool(not np.isfinite(final) or final > 1e3),
                "mean_staleness": r.mean_staleness,
            })
    us = (time.perf_counter() - t0) * 1e6 / max(len(rows), 1)

    def _fmt(row):
        tag = "DIV" if row["diverged"] else f"{row['final_rel_error']:.1e}"
        return (f"{row['policy']}xD{row['max_staleness']}:"
                f"R={row['rounds_to_eq']},err={tag}")

    emit("async_policy_rescue", us, ";".join(_fmt(r) for r in rows))
    return rows


def main() -> None:
    import argparse

    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--tau", type=int, default=4)
    parser.add_argument("--rounds", type=int, default=3000)
    parser.add_argument("--threshold", type=float, default=1e-6)
    parser.add_argument("--policy-rounds", type=int, default=2500,
                        help="budget for the fixed-vs-adaptive strong-"
                             "coupling sweep (adaptive needs ~2100 rounds "
                             "to reach 1e-6)")
    parser.add_argument("--json", type=str, default=None, metavar="PATH",
                        help="write the sweeps as structured JSON "
                             "(BENCH_async.json convention for tracking)")
    args = parser.parse_args()

    rows = run_staleness(tau=args.tau, rounds=args.rounds,
                         threshold=args.threshold)
    policy_rows = run_policy_rescue(tau=args.tau, rounds=args.policy_rounds,
                                    threshold=args.threshold)
    if args.json:
        payload = {"benchmark": "bench_async", "staleness": rows,
                   "policy_rescue": policy_rows}
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=2)
        print(f"wrote {args.json}", flush=True)


if __name__ == "__main__":
    main()
