"""Selection-policy sweep: bytes-to-equilibrium of value-driven participation.

The headline question for the selection axis (ROADMAP item 4): when only a
``fraction`` of players may talk per round, does choosing WHO by observed
contribution (GTG-Shapley greedy, UCB bandit, power-of-choice) beat the
value-blind uniform draw at the same budget? The separating regime is
warm-start heterogeneity: most players start AT the equilibrium and two
start far, so a uniform draw wastes most of its slots re-synchronizing
players who are done (and whose best-response to far-away opponents
actively moves them OFF the equilibrium), while a value-driven policy
routes the budget to the players carrying the error.

Three sweeps, one artifact (``BENCH_selection.json``):

- ``selection``: greedy vs UCB vs power-of-choice vs the uniform control at
  a fixed fraction on the warm-start quadratic game — rounds and wire bytes
  to the 1e-3 neighborhood (the acceptance headline: greedy strictly beats
  uniform on bytes-to-eq).
- ``mean_field``: the same contest composed with ``MeanFieldView(sample=k)``
  — selection is the one mask strategy the sampled summary path admits
  (absentees stay stale in the live snapshot the sampled reads index).
- ``staleness``: the composition probe — can value-driven selection rescue
  the strong-coupling straggler regime where the fixed Theorem 3.4 step
  size fails and ``delay_adaptive`` succeeds? Honest outcome (recorded so
  nobody over-claims): NO. Deterministic value-driven masks act like
  adversarial staleness at strong coupling — freezing a chosen block for
  several rounds is exactly the perturbation the antisymmetric coupling
  amplifies — while the uniform draw's randomness averages the same
  exclusions out. Value-driven selection is a weak-coupling /
  heterogeneous-progress tool, not a stability device.

``python -m benchmarks.bench_selection --json BENCH_selection.json`` writes
the artifact; ``scripts/render_experiments.py`` renders it into
EXPERIMENTS.md and ``scripts/check_bench_drift.py`` guards it.
"""

from __future__ import annotations

import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.core import stepsize
from repro.core.async_engine import AsyncPearlEngine, StragglerDelay
from repro.core.engine import MeanFieldView, PearlEngine
from repro.core.games import make_mean_field_game, make_quadratic_game
from repro.core.metrics import rounds_to_reach
from repro.core.selection import SELECTION_POLICIES

POLICY_ORDER = ("greedy_shapley", "ucb", "power_of_choice", "uniform")


def _policy(name: str, fraction: float, **kw):
    return SELECTION_POLICIES[name](fraction=fraction, **kw)


def warm_start_game(n: int = 10, d: int = 10, far: int = 2,
                    scale: float = 10.0):
    """The separating config: ``far`` players start ``scale`` Gaussians away
    from the equilibrium, everyone else starts ON it."""
    game = make_quadratic_game(n=n, d=d, M=40, L_B=1.0, batch_size=1, seed=1)
    off = np.zeros((n, d))
    off[:far] = scale * np.random.default_rng(3).standard_normal((far, d))
    x0 = jnp.asarray(np.asarray(game.equilibrium()) + off, jnp.float32)
    return game, x0


def _row(name, r, threshold, rounds, **extra):
    hit = rounds_to_reach(r.rel_errors, threshold)
    final = float(r.rel_errors[-1])
    per_round = r.bytes_up + r.bytes_down
    return {
        "policy": name,
        "rounds": rounds,   # the budget, for budget-aware drift checks
        "rounds_to_eq": hit,
        "bytes_to_eq": (int(per_round[:hit].sum())
                        if hit is not None else None),
        "final_rel_error": final,
        "diverged": bool(not np.isfinite(final) or final > 1e3),
        "bytes_per_round": int(per_round[0]),
        **extra,
    }


def run_selection(tau: int = 4, rounds: int = 600, threshold: float = 1e-3,
                  fraction: float = 0.2):
    """Greedy vs UCB vs power-of-choice vs uniform at a fixed budget on the
    warm-start heterogeneity game (deterministic gradients; one shared
    Theorem 3.4 step size, so the contest is pure participation pattern)."""
    game, x0 = warm_start_game()
    gamma = stepsize.gamma_constant(game.constants(), tau)

    rows = []
    t0 = time.perf_counter()
    for name in POLICY_ORDER:
        r = PearlEngine(sync=_policy(name, fraction)).run(
            game, x0, tau=tau, rounds=rounds, gamma=gamma,
            key=jax.random.PRNGKey(0), stochastic=False,
        )
        rows.append(_row(name, r, threshold, rounds,
                         fraction=fraction, tau=tau))
    us = (time.perf_counter() - t0) * 1e6 / max(len(rows), 1)

    emit("selection", us,
         ";".join(f"{r['policy']}:R={r['rounds_to_eq']},"
                  f"B={r['bytes_to_eq']}" for r in rows))
    return rows


def run_mean_field(tau: int = 4, rounds: int = 400, threshold: float = 1e-2,
                   fraction: float = 0.2, sample: int = 8):
    """Selection x sampled mean-field: the O(d)-downlink population with a
    participation budget. Uniform is the control at the same fraction and
    the same sampled-interaction seed."""
    game = make_mean_field_game(n=50, d=6, heterogeneity=1.0, seed=0)
    gamma = stepsize.gamma_constant(game.constants(), tau)
    x0 = jnp.zeros((game.n, game.d))

    rows = []
    t0 = time.perf_counter()
    for name in ("greedy_shapley", "uniform"):
        r = PearlEngine(sync=_policy(name, fraction),
                        view=MeanFieldView(sample=sample, seed=0)).run(
            game, x0, tau=tau, rounds=rounds, gamma=gamma,
            key=jax.random.PRNGKey(0), stochastic=False,
        )
        rows.append(_row(name, r, threshold, rounds, fraction=fraction,
                         tau=tau, n=game.n, sample=sample))
    us = (time.perf_counter() - t0) * 1e6 / max(len(rows), 1)

    emit("selection_mean_field", us,
         ";".join(f"{r['policy']}:R={r['rounds_to_eq']},"
                  f"err={r['final_rel_error']:.1e}" for r in rows))
    return rows


def run_staleness_composition(tau: int = 4, rounds: int = 2500,
                              threshold: float = 1e-6):
    """Value-driven selection under strong-coupling stragglers — the honest
    negative. Grid: step-size policy (theorem34 | delay_adaptive) x
    selection (uniform | staleness-penalized greedy) at D = 16 on the
    bench_async policy-rescue game. The delay-adaptive x uniform cell
    converges; BOTH greedy cells fail — deterministic exclusion at strong
    coupling is adversarial staleness, and no step-size policy rescues it."""
    game = make_quadratic_game(n=6, d=10, M=40, L_B=5.0, batch_size=1,
                               seed=0)
    gamma = stepsize.gamma_constant(game.constants(), tau)
    x0 = jnp.asarray(
        np.random.default_rng(0).standard_normal((game.n, game.d)),
        dtype=jnp.float32,
    )
    sched = StragglerDelay(fraction=0.25, seed=0)
    selections = {
        "uniform": _policy("uniform", 0.5),
        "greedy_shapley": _policy("greedy_shapley", 0.5,
                                  staleness_penalty=0.1),
    }

    rows = []
    t0 = time.perf_counter()
    for pname in ("theorem34", "delay_adaptive"):
        for sname, sync in selections.items():
            r = AsyncPearlEngine(sync=sync, delays=sched, max_staleness=16,
                                 policy=pname).run(
                game, x0, tau=tau, rounds=rounds, gamma=gamma,
                key=jax.random.PRNGKey(0), stochastic=False,
            )
            rows.append(_row(sname, r, threshold, rounds,
                             stepsize_policy=pname, max_staleness=16,
                             tau=tau, mean_staleness=r.mean_staleness))
    us = (time.perf_counter() - t0) * 1e6 / max(len(rows), 1)

    def _fmt(row):
        tag = "DIV" if row["diverged"] else f"{row['final_rel_error']:.1e}"
        return f"{row['stepsize_policy']}x{row['policy']}:err={tag}"

    emit("selection_staleness", us, ";".join(_fmt(r) for r in rows))
    return rows


def main() -> None:
    import argparse

    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--tau", type=int, default=4)
    parser.add_argument("--rounds", type=int, default=600,
                        help="budget for the warm-start selection contest")
    parser.add_argument("--threshold", type=float, default=1e-3)
    parser.add_argument("--mean-field-rounds", type=int, default=400)
    parser.add_argument("--staleness-rounds", type=int, default=2500)
    parser.add_argument("--json", type=str, default=None, metavar="PATH",
                        help="write the sweeps as structured JSON "
                             "(BENCH_selection.json convention)")
    args = parser.parse_args()

    rows = run_selection(tau=args.tau, rounds=args.rounds,
                         threshold=args.threshold)
    mf_rows = run_mean_field(tau=args.tau, rounds=args.mean_field_rounds)
    st_rows = run_staleness_composition(tau=args.tau,
                                        rounds=args.staleness_rounds)
    if args.json:
        payload = {"benchmark": "bench_selection", "selection": rows,
                   "mean_field": mf_rows, "staleness": st_rows}
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=2)
        print(f"wrote {args.json}", flush=True)


if __name__ == "__main__":
    main()
