"""Shared benchmark plumbing: timing + CSV emission.

Every benchmark prints ``name,us_per_call,derived`` rows (one per paper
table/figure artifact) so ``python -m benchmarks.run`` output is machine
readable; ``derived`` carries the figure-specific metric.
"""

from __future__ import annotations

import time
from typing import Callable

import jax


def time_fn(fn: Callable, *args, iters: int = 3, warmup: int = 1) -> float:
    """Median wall-time per call in microseconds (blocks on jax outputs)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] * 1e6


def emit(name: str, us_per_call: float, derived: str) -> None:
    print(f"{name},{us_per_call:.1f},{derived}", flush=True)
