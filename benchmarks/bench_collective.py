"""Wire-bytes sweep for the sharded collective layer (BENCH_collective.json).

Two sweeps over the explicit shard_map lowering of repro.core.collective,
run on a fake multi-device mesh (CI: ``XLA_FLAGS=
--xla_force_host_platform_device_count=8``):

- ``run_wire``: lower each collective (trainer star mean / engine star
  gather / ring Metropolis sweep) x (exact f32 | bf16) and read the wire
  DIRECTLY off the compiled HLO — operand dtypes and per-participant operand
  bytes of every cross-player collective. The bf16 rows must show 2-byte
  operands and half the f32 bytes; this is the claim the byte accounting
  used to assert on faith (the PR 1 negative result: the host lowering's
  compiled wire stayed f32).
- ``run_parity``: the same game under host vs mesh lowering — final
  relative errors must agree (exactly-ish in f32, bounded quantization
  noise in bf16), so the explicit wire changes the program, not the
  trajectory.

Skips gracefully (empty sweeps, a note on stdout) when only one device is
available — the artifact is produced by the multi-device CI job.
"""

from __future__ import annotations

import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.core import collective, stepsize
from repro.core.engine import ExactSync, PearlEngine, QuantizedSync
from repro.core.games import make_quadratic_game
from repro.core.topology import Ring

N, D = 8, 4096     # 8 players so the fake CI mesh is fully populated

SYNCS = {
    "exact": ExactSync(),
    "bf16": QuantizedSync(jnp.bfloat16),
}


def _mesh_or_none():
    try:
        return collective.player_mesh(N)
    except ValueError:
        return None


def _wire_row(name: str, sname: str, hlo: str) -> dict:
    report = collective.wire_dtype_report(hlo)
    collective.assert_wire_dtype(hlo, compressed=(sname == "bf16"))
    return {
        "collective": name,
        "sync": sname,
        "wire_dtypes": sorted({o.operand_dtype for o in report}),
        "wire_ops": sorted({o.op for o in report}),
        "wire_bytes_per_round": int(sum(o.operand_bytes for o in report)),
        "compressed_wire": bool(collective.compressed_wire_ops(hlo)),
    }


def run_wire():
    """Operand dtype + bytes of each compiled collective, per sync strategy.

    ``wire_bytes_per_round`` sums the per-participant operand bytes of every
    cross-player collective in the lowering — the quantity that must halve
    when the wire is bf16 (exact 2x: same shapes, half the itemsize).
    """
    mesh = _mesh_or_none()
    if mesh is None:
        emit("collective_wire", 0.0, "skipped: single-device (set XLA_FLAGS="
             "--xla_force_host_platform_device_count=8)")
        return []
    x = jnp.zeros((N, D), jnp.float32)
    V = jnp.zeros((N, N, D), jnp.float32)
    W = jnp.asarray(Ring().mixing_matrix(N), jnp.float32)
    A = Ring().adjacency(N)
    link_w = jnp.where(jnp.asarray(A), W, 0.0)
    self_w = 1.0 - jnp.sum(link_w, axis=1)
    offsets = collective.circulant_offsets(A)

    rows = []
    t0 = time.perf_counter()
    for sname, sync in SYNCS.items():
        lowerings = {
            "tree_mean": lambda s=sync: jax.jit(
                lambda t: collective.sharded_tree_mean(t, mesh=mesh, sync=s)
            ).lower({"w": x}),
            "star_gather": lambda s=sync: jax.jit(
                lambda t: collective.sharded_joint_wire(t, mesh=mesh, sync=s)
            ).lower(x),
            "ring_permute": lambda s=sync: jax.jit(
                lambda v, lw, sw: collective.sharded_mix_sweep(
                    v, lw, sw, mesh=mesh, sync=s, offsets=offsets)
            ).lower(V, link_w, self_w),
            "gather_relay": lambda s=sync: jax.jit(
                lambda v, lw, sw: collective.sharded_mix_sweep(
                    v, lw, sw, mesh=mesh, sync=s, offsets=None)
            ).lower(V, link_w, self_w),
        }
        for name, lower in lowerings.items():
            hlo = lower().compile().as_text()
            rows.append(_wire_row(name, sname, hlo))
    us = (time.perf_counter() - t0) * 1e6 / max(len(rows), 1)

    # the headline: per collective, bf16 wire bytes must be exactly half f32
    by_name = {}
    for r in rows:
        by_name.setdefault(r["collective"], {})[r["sync"]] = r
    for name, cells in by_name.items():
        f32b = cells["exact"]["wire_bytes_per_round"]
        bf16b = cells["bf16"]["wire_bytes_per_round"]
        assert bf16b * 2 == f32b, (name, bf16b, f32b)

    derived = ";".join(
        f"{r['collective']}x{r['sync']}:"
        f"{'+'.join(r['wire_dtypes'])},B={r['wire_bytes_per_round']}"
        for r in rows
    )
    emit("collective_wire", us, derived)
    return rows


def run_parity(tau: int = 4, rounds: int = 400):
    """Host vs mesh lowering on the same game: the wire must not move the
    trajectory beyond (f32) fusion-level or (bf16) quantization-level noise.
    """
    mesh = _mesh_or_none()
    if mesh is None:
        emit("collective_parity", 0.0, "skipped: single-device")
        return []
    game = make_quadratic_game(n=N, d=10, M=40, L_B=1.0, batch_size=1, seed=0)
    c = game.constants()
    gamma = stepsize.gamma_constant(c, tau)
    x0 = jnp.asarray(
        np.random.default_rng(0).standard_normal((game.n, game.d)),
        dtype=jnp.float32,
    )

    cells = [
        ("star", "exact", {}, {}),
        ("star", "bf16", {"sync": QuantizedSync(jnp.bfloat16)}, {}),
        ("ring", "exact", {"topology": Ring()}, {}),
        ("ring", "bf16", {"sync": QuantizedSync(jnp.bfloat16),
                          "topology": Ring()}, {}),
    ]
    rows = []
    t0 = time.perf_counter()
    for tname, sname, kwargs, _ in cells:
        host = PearlEngine(**kwargs).run(
            game, x0, tau=tau, rounds=rounds, gamma=gamma, stochastic=False)
        mesh_r = PearlEngine(mesh=mesh, **kwargs).run(
            game, x0, tau=tau, rounds=rounds, gamma=gamma, stochastic=False)
        drift = float(np.abs(np.asarray(host.x_final)
                             - np.asarray(mesh_r.x_final)).max())
        rows.append({
            "topology": tname,
            "sync": sname,
            "rounds": rounds,
            "host_rel_error": float(host.rel_errors[-1]),
            "mesh_rel_error": float(mesh_r.rel_errors[-1]),
            "max_final_drift": drift,
        })
    us = (time.perf_counter() - t0) * 1e6 / len(rows)

    derived = ";".join(
        f"{r['topology']}x{r['sync']}:drift={r['max_final_drift']:.1e}"
        for r in rows
    )
    emit("collective_parity", us, derived)
    return rows


def main() -> None:
    import argparse

    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--rounds", type=int, default=400)
    parser.add_argument("--json", type=str, default=None, metavar="PATH",
                        help="write the sweeps as structured JSON "
                             "(BENCH_collective.json convention)")
    args = parser.parse_args()

    wire = run_wire()
    parity = run_parity(rounds=args.rounds)
    if args.json:
        payload = {
            "benchmark": "bench_collective",
            "wire": wire,
            "parity": parity,
        }
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=2)
        print(f"wrote {args.json}", flush=True)


if __name__ == "__main__":
    main()
